"""Bit-for-bit equivalence between the vectorized and scalar Step-2
engines (emulator + memory tracker + knapsack scoring) on random DAGs.

These tests intentionally avoid hypothesis so the equivalence guarantee
is exercised even in minimal environments: 50+ seeded random DAGs with
varying size, degree, device count, and comm scaling.
"""
import numpy as np
import pytest

from repro.core.emulator import emulate, emulate_scalar, emulate_vectorized
from repro.core.graph import CostGraph, random_dag
from repro.core.memops import (IncrementalMemoryTracker,
                               compute_profile_scalar,
                               compute_profile_vectorized,
                               memory_potentials_scalar,
                               memory_potentials_vectorized)
from repro.core.overflow import move_cost, move_costs
from repro.core.partitioner import PardnnOptions, pardnn_partition


def _case(seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 400))
    k = int(rng.integers(1, 7))
    g = random_dag(n, avg_deg=float(rng.uniform(0.3, 4.0)), seed=seed,
                   frac_residual=float(rng.uniform(0.0, 0.3)))
    assignment = rng.integers(0, k, size=n).astype(np.int64)
    comm_scale = float(rng.uniform(0.2, 2.0))
    return g, assignment, k, comm_scale


SEEDS = list(range(50))


@pytest.mark.parametrize("seed", SEEDS)
def test_emulator_engines_identical(seed):
    g, a, k, cs = _case(seed)
    s1 = emulate_scalar(g, a, k, cs)
    s2 = emulate_vectorized(g, a, k, cs)
    assert np.array_equal(s1.st, s2.st)
    assert np.array_equal(s1.ft, s2.ft)
    assert s1.makespan == s2.makespan
    assert np.array_equal(s1.exec_order, s2.exec_order)
    assert np.array_equal(s1.pe_busy, s2.pe_busy)


@pytest.mark.parametrize("seed", SEEDS)
def test_memory_profile_engines_identical(seed):
    g, a, k, cs = _case(seed)
    sched = emulate_vectorized(g, a, k, cs)
    p1 = compute_profile_scalar(g, a, sched, k)
    p2 = compute_profile_vectorized(g, a, sched, k)
    assert np.array_equal(p1.peak, p2.peak)
    assert np.array_equal(p1.peak_time, p2.peak_time)
    assert np.array_equal(p1.residual, p2.residual)
    for u in range(g.n):
        for pe in range(k):
            assert p1.last_consumer_on(u, pe) == p2.last_consumer_on(u, pe)


@pytest.mark.parametrize("seed", SEEDS[:20])
def test_memory_potentials_engines_identical(seed):
    g, a, k, cs = _case(seed)
    sched = emulate_vectorized(g, a, k, cs)
    p1 = compute_profile_scalar(g, a, sched, k)
    p2 = compute_profile_vectorized(g, a, sched, k)
    for pe in range(k):
        t = float(p1.peak_time[pe])
        d1 = memory_potentials_scalar(g, a, sched, p1, pe, t)
        d2 = memory_potentials_vectorized(g, a, sched, p2, pe, t)
        assert d1 == d2


@pytest.mark.parametrize("seed", SEEDS[:20])
def test_move_cost_batch_matches_scalar(seed):
    g, a, k, _ = _case(seed)
    nodes = np.arange(g.n, dtype=np.int64)
    batch = move_costs(g, a, nodes)
    for u in range(g.n):
        assert batch[u] == move_cost(g, a, int(u))


@pytest.mark.parametrize("seed", SEEDS[:10])
def test_engine_dispatch_and_env_flag(seed):
    g, a, k, cs = _case(seed)
    s_default = emulate(g, a, k, cs)
    s_vec = emulate(g, a, k, cs, engine="vector")
    s_scal = emulate(g, a, k, cs, engine="scalar")
    assert np.array_equal(s_default.st, s_vec.st)
    assert np.array_equal(s_vec.st, s_scal.st)
    with pytest.raises(ValueError):
        emulate(g, a, k, cs, engine="warp-drive")


@pytest.mark.parametrize("seed", SEEDS[:12])
def test_incremental_tracker_matches_recompute(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 250))
    k = int(rng.integers(2, 5))
    g = random_dag(n, avg_deg=2.0, seed=seed, frac_residual=0.15)
    a = rng.integers(0, k, size=n).astype(np.int64)
    sched = emulate_vectorized(g, a, k)
    tracker = IncrementalMemoryTracker(g, a, sched, k)
    prof = compute_profile_vectorized(g, a, sched, k)
    assert np.allclose(tracker.peaks(), prof.peak, rtol=1e-12, atol=1e-9)
    for _ in range(25):
        u = int(rng.integers(0, n))
        if int(g.ntype[u]) == 2:      # REF nodes move with their variable
            continue
        to_pe = int(rng.integers(0, k))
        token = tracker.apply_move(u, to_pe)
        ref = compute_profile_vectorized(g, a, sched, k)
        assert np.allclose(tracker.peaks(), ref.peak, rtol=1e-12, atol=1e-9)
        if rng.random() < 0.3:
            tracker.revert(token)
            ref = compute_profile_vectorized(g, a, sched, k)
            assert np.allclose(tracker.peaks(), ref.peak,
                               rtol=1e-12, atol=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_full_partitioner_identical_across_engines(seed):
    """pardnn_partition end-to-end yields the same placement, makespan,
    and peaks whichever engine drives Step-2."""
    rng = np.random.default_rng(seed)
    g = random_dag(int(rng.integers(50, 250)), avg_deg=2.5, seed=seed,
                   frac_residual=0.1)
    k = int(rng.integers(2, 5))
    p_vec = pardnn_partition(g, k, options=PardnnOptions(engine="vector"))
    p_scal = pardnn_partition(g, k, options=PardnnOptions(engine="scalar"))
    assert np.array_equal(p_vec.assignment, p_scal.assignment)
    assert p_vec.makespan == p_scal.makespan
    assert np.array_equal(p_vec.peak_mem, p_scal.peak_mem)
    # and under memory pressure (knapsack path, shared tracker)
    cap = float(max(p_vec.peak_mem)) * 0.8 + 1e-9
    q_vec = pardnn_partition(g, k, mem_caps=cap / 0.9,
                             options=PardnnOptions(engine="vector"))
    q_scal = pardnn_partition(g, k, mem_caps=cap / 0.9,
                              options=PardnnOptions(engine="scalar"))
    assert np.array_equal(q_vec.assignment, q_scal.assignment)
    assert q_vec.makespan == q_scal.makespan


def test_vectorized_handles_empty_and_trivial_graphs():
    g = CostGraph()
    g.finalize()
    s = emulate_vectorized(g, np.zeros(0, dtype=np.int64), 2)
    assert s.makespan == 0.0
    g2 = CostGraph()
    g2.add_node(comp=1.5)
    g2.finalize()
    s2 = emulate_vectorized(g2, np.zeros(1, dtype=np.int64), 1)
    assert s2.makespan == pytest.approx(1.5)
    p2 = compute_profile_vectorized(g2, np.zeros(1, dtype=np.int64), s2, 1)
    assert p2.peak.shape == (1,)


@pytest.mark.parametrize("seed", SEEDS[:25])
def test_mapping_csr_helpers_match_scalar(seed):
    """The CSR-gather `_cluster_comm`/`_comm_per_pe` must agree with the
    python edge-loop references, and `map_clusters` must produce the
    identical assignment whichever pair drives it."""
    import repro.core.mapping as M
    from repro.core.slicing import slice_graph
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 400))
    k = int(rng.integers(2, 7))
    g = random_dag(n, avg_deg=float(rng.uniform(0.5, 4.0)), seed=seed)
    s = slice_graph(g, k)
    a = rng.integers(-1, k, size=n).astype(np.int64)
    for cl in s.secondaries[:8]:
        in_sc = np.zeros(n, dtype=bool)
        in_sc[cl] = True
        assert np.isclose(M._cluster_comm(g, in_sc, cl),
                          M._cluster_comm_scalar(g, in_sc, cl),
                          rtol=1e-12, atol=1e-12)
        assert np.allclose(M._comm_per_pe(g, a, cl, k),
                           M._comm_per_pe_scalar(g, a, cl, k),
                           rtol=1e-12, atol=1e-12)
    m_vec = M.map_clusters(g, s)
    orig = (M._cluster_comm, M._comm_per_pe)
    M._cluster_comm, M._comm_per_pe = (M._cluster_comm_scalar,
                                       M._comm_per_pe_scalar)
    try:
        m_ref = M.map_clusters(g, s)
    finally:
        M._cluster_comm, M._comm_per_pe = orig
    assert np.array_equal(m_vec.assignment, m_ref.assignment)
    assert m_vec.secondary_pe == m_ref.secondary_pe


def test_repeated_calls_reuse_scratch_without_aliasing():
    """The vectorized engine's thread-local scratch buffers are reused
    across calls; arrays escaping into earlier Schedules must stay valid
    (freshly allocated), not be silently overwritten by a later call."""
    g1 = random_dag(120, avg_deg=2.0, seed=11)
    g2 = random_dag(300, avg_deg=2.5, seed=12)   # forces scratch growth
    a1 = (np.arange(g1.n) % 3).astype(np.int64)
    a2 = (np.arange(g2.n) % 4).astype(np.int64)
    s1 = emulate_vectorized(g1, a1, 3)
    st1, ft1 = s1.st.copy(), s1.ft.copy()
    order1 = s1.exec_order.copy()
    for _ in range(3):
        emulate_vectorized(g2, a2, 4)
        emulate_vectorized(g1, a1, 3)
    assert np.array_equal(s1.st, st1)
    assert np.array_equal(s1.ft, ft1)
    assert np.array_equal(s1.exec_order, order1)
    # and the reused path still matches the scalar engine exactly
    s1b = emulate_vectorized(g1, a1, 3)
    ref = emulate_scalar(g1, a1, 3)
    assert np.array_equal(s1b.st, ref.st)
    assert np.array_equal(s1b.ft, ref.ft)


def test_vectorized_zero_cost_ties_terminate():
    """Zero-comp chains exercise the degenerate single-step fallback."""
    g = CostGraph()
    ids = [g.add_node(comp=0.0) for _ in range(6)]
    for u, v in zip(ids, ids[1:]):
        g.add_edge(u, v, comm=0.0)
    g.finalize()
    a = np.zeros(6, dtype=np.int64)
    s = emulate_vectorized(g, a, 2)
    assert s.makespan == 0.0
    assert np.all(s.ft >= s.st)
