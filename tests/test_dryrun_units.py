"""Dry-run machinery units (no 512-device compile): HLO collective
parser, roofline terms, model-FLOPs accounting, skip matrix."""
import pytest

from repro.launch.dryrun import (collective_bytes_from_hlo, model_flops,
                                 roofline_terms)
from repro.configs import SHAPES, get_config

HLO_SAMPLE = """
ENTRY %main {
  %p0 = bf16[128,4096]{1,0} parameter(0)
  %ag = bf16[2048,4096]{1,0} all-gather(bf16[128,4096]{1,0} %p0), replica_groups=[16,16]<=[256]
  %ar = f32[512,512]{1,0} all-reduce(f32[512,512]{1,0} %x), to_apply=%add
  %ags = bf16[64,64]{1,0} all-gather-start(bf16[8,64]{1,0} %p1)
  %agd = bf16[64,64]{1,0} all-gather-done(%ags)
  %cp = bf16[32,32]{1,0} collective-permute(bf16[32,32]{1,0} %y), source_target_pairs={{0,1}}
}
"""


def test_collective_parser_sums_operands_not_results():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    # all-gather operand: 128*4096*2 bytes; -start counted, -done skipped
    assert out["bytes"]["all-gather"] == 128 * 4096 * 2 + 8 * 64 * 2
    assert out["bytes"]["all-reduce"] == 512 * 512 * 4
    assert out["bytes"]["collective-permute"] == 32 * 32 * 2
    assert out["counts"]["all-gather"] == 2
    assert out["total_bytes"] > 0


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12 * 256, hbm_bytes=0, coll_bytes=0,
                       chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute"
    t = roofline_terms(flops=0, hbm_bytes=819e9 * 256 * 2, coll_bytes=0,
                       chips=256)
    assert t["dominant"] == "memory" and t["bound_s"] == pytest.approx(2.0)
    t = roofline_terms(flops=0, hbm_bytes=0, coll_bytes=50e9 * 256 * 3,
                       chips=256)
    assert t["dominant"] == "collective"
    assert t["bound_s"] == pytest.approx(3.0)


def test_model_flops_train_vs_decode():
    cfg = get_config("granite-8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    de = model_flops(cfg, SHAPES["decode_32k"])
    tokens_train = 256 * 4096
    assert tr == pytest.approx(6 * cfg.param_count() * tokens_train,
                               rel=1e-6)
    assert de == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)


def test_moe_uses_active_params():
    cfg = get_config("mixtral-8x7b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    assert tr < 6 * cfg.param_count() * 256 * 4096
    assert tr == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)


def test_attention_score_traffic_estimator():
    from benchmarks.roofline import attention_score_traffic
    # swa arch charges window, not full seq
    swa = attention_score_traffic("mixtral-8x7b", "train_4k")
    cfgm = get_config("mixtral-8x7b")
    expect = (256 * cfgm.num_heads * 4096 *
              min(cfgm.sliding_window, 4096) * 4.0 * 4.0 * 32)
    assert swa == pytest.approx(expect)
    # attention-free arch: zero
    assert attention_score_traffic("rwkv6-7b", "train_4k") == 0.0
