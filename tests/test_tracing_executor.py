"""jaxpr → CostGraph tracing and the placed graph executor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pardnn_partition
from repro.core.executor import execute
from repro.core.tracing import trace_cost_graph


def _mlp(params, x):
    def layer(h, p):
        w1, w2 = p
        h = jnp.tanh(h @ w1) @ w2
        return h, jnp.sum(h)
    h, sums = jax.lax.scan(layer, x, params)
    return jnp.mean(h ** 2) + jnp.sum(sums)


def _example():
    key = jax.random.PRNGKey(0)
    L, D, H = 4, 16, 32
    params = (jax.random.normal(key, (L, D, H)) * 0.1,
              jax.random.normal(key, (L, H, D)) * 0.1)
    x = jax.random.normal(key, (3, D))
    return params, x


def test_trace_produces_dag_with_scan_unrolled():
    params, x = _example()
    g = trace_cost_graph(_mlp, params, x, max_scan_unroll=16)
    # 4 iterations x (2 dots + tanh + sum) plus top-level ops
    dots = sum(1 for n in g.names if n == "dot_general")
    assert dots == 8
    assert g.n > 12
    g.topo_order()  # acyclic


def test_trace_costs_positive_and_memory_assigned():
    params, x = _example()
    g = trace_cost_graph(_mlp, params, x)
    assert float(np.sum(g.comp)) > 0
    assert float(np.sum(g.mem)) > 0


def test_executor_matches_reference_unplaced():
    params, x = _example()
    g, prog = trace_cost_graph(_mlp, params, x, record=True)
    ref = _mlp(params, x)
    out = execute(prog, None, None, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


def test_executor_matches_reference_with_placement():
    """The paper's pipeline: placement file -> execution engine."""
    params, x = _example()
    g, prog = trace_cost_graph(_mlp, params, x, record=True)
    p = pardnn_partition(g, 2)
    devs = list(jax.devices()) * 2
    out = execute(prog, p.assignment, devs, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_mlp(params, x)),
                               rtol=1e-5)


def test_trace_grad_graph_partitionable():
    params, x = _example()
    g = trace_cost_graph(jax.grad(_mlp), params, x)
    p = pardnn_partition(g, 4, mem_caps=1e9)
    assert p.makespan > 0
    assert (p.assignment >= 0).all()


def test_reverse_scan_replay_exact():
    """An explicit ``reverse=True`` scan consumes xs back-to-front and
    its stacked ys mirror the xs indices — the recorded slice/stack
    nodes must honor that, not assume forward order."""
    xs = jnp.arange(1.0, 6.0)[:, None] * jnp.ones((5, 3))

    def fn(xs):
        def step(c, x):
            c = c * 0.5 + x
            return c, c
        carry, ys = jax.lax.scan(step, jnp.zeros(3), xs, reverse=True)
        return jnp.sum(carry) + jnp.sum(ys * jnp.arange(5.0)[:, None])

    ref = fn(xs)
    g, prog = trace_cost_graph(fn, xs, record=True)
    out = execute(prog, None, None, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_grad_of_scan_replay_exact():
    """Regression: ``jax.grad`` of a scan emits a *reverse* scan for the
    backward pass; the tracer used to ignore ``reverse`` and replay the
    backward slices in forward order, silently corrupting every scanned
    model's gradients (caught by the scenario matrix on hubert/jamba)."""
    params, x = _example()
    grad_fn = jax.grad(_mlp)
    ref = grad_fn(params, x)
    g, prog = trace_cost_graph(grad_fn, params, x, record=True)
    out = execute(prog, None, None, params, x)
    for r, o in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


def test_moe_topk_routing_replay():
    """MoE-style routing (softmax gate → top_k → one-hot dispatch) mixes
    value and index outputs; the recorded program must replay both."""
    key = jax.random.PRNGKey(1)
    T, E, D = 6, 4, 8
    wg = jax.random.normal(key, (D, E)) * 0.3
    we = jax.random.normal(key, (E, D, D)) * 0.1
    x = jax.random.normal(key, (T, D))

    def moe(wg, we, x):
        gates = jax.nn.softmax(x @ wg, axis=-1)
        top, idx = jax.lax.top_k(gates, 2)
        top = top / jnp.sum(top, axis=-1, keepdims=True)
        disp = jax.nn.one_hot(idx, E) * top[..., None]   # [T, 2, E]
        expert_out = jnp.einsum("td,edh->teh", x, we)    # [T, E, D]
        out = jnp.einsum("tke,teh->th", disp, expert_out)
        return jnp.sum(out ** 2)

    ref = moe(wg, we, x)
    g, prog = trace_cost_graph(moe, wg, we, x, record=True)
    out = execute(prog, None, None, wg, we, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)

    grad_ref = jax.grad(moe)(wg, we, x)
    g2, prog2 = trace_cost_graph(jax.grad(moe), wg, we, x, record=True)
    grad_out = execute(prog2, None, None, wg, we, x)
    np.testing.assert_allclose(np.asarray(grad_out), np.asarray(grad_ref),
                               rtol=1e-5, atol=1e-6)


def test_trace_real_model():
    from repro.configs import get_config, reduced
    from repro.models import init_params, loss_fn
    cfg = reduced(get_config("repro-lm-100m"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32)}
    g = trace_cost_graph(lambda p: loss_fn(cfg, p, batch)[0], params)
    assert g.n > 100
    p = pardnn_partition(g, 4)
    assert p.makespan > 0
