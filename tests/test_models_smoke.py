"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finite values; decode path equals full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config, reduced,
                           shape_skip_reason)
from repro.models import (decode_step, init_params, loss_fn, prefill)
from repro.models.transformer import embed_inputs, forward, lm_head_weight

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.frontend is not None:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32) * 0.1,
                "targets": jax.random.randint(KEY, (B, S), 0,
                                              cfg.vocab_size)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    assert 3.0 < float(loss) < 12.0  # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_full_forward(arch):
    """KV/state caches (GQA, MLA-absorbed, Mamba, RWKV) are exact."""
    cfg = reduced(get_config(arch))
    reason = shape_skip_reason(cfg, SHAPES["decode_32k"])
    if reason:
        pytest.skip(f"{arch}: {reason}")
    params = init_params(cfg, KEY)
    B, S, MAX = 2, 12, 24
    batch = _batch(cfg, B, S)
    batch.pop("targets")
    x = embed_inputs(cfg, params, batch)
    hid, _, _ = forward(cfg, params, x, positions=jnp.arange(S))
    ref_last = (hid[:, -1:] @ lm_head_weight(cfg, params)
                ).astype(jnp.float32)
    bp = {k: v[:, :S - 1] for k, v in batch.items()}
    _, caches = prefill(cfg, params, bp, MAX)
    last = (batch["tokens"][:, S - 1:] if cfg.frontend is None
            else batch["embeds"][:, S - 1:])
    logits, _ = decode_step(cfg, params, caches, last, S - 1)
    np.testing.assert_allclose(logits[:, 0], ref_last[:, 0],
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_gradients_finite(arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    (_, _), grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True))(params)
    for g in jax.tree_util.tree_leaves(grads):
        assert jnp.all(jnp.isfinite(g))


def test_remat_policies_equal_loss():
    cfg = reduced(get_config("granite-8b"))
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    losses = [float(jax.jit(lambda p: loss_fn(cfg, p, batch,
                                              remat_policy=pol)[0])(params))
              for pol in ("none", "full", "dots")]
    assert max(losses) - min(losses) < 1e-5


def test_vector_cache_pos_matches_scalar():
    """Continuous batching: per-slot positions == scalar positions when
    uniform (serving engine invariant)."""
    cfg = reduced(get_config("granite-8b"))
    params = init_params(cfg, KEY)
    B, S, MAX = 2, 8, 16
    batch = _batch(cfg, B, S)
    batch.pop("targets")
    _, caches = prefill(cfg, params, batch, MAX)
    tok = jnp.zeros((B, 1), jnp.int32)
    l_scalar, _ = decode_step(cfg, params, caches, tok, S)
    l_vec, _ = decode_step(cfg, params, caches, tok,
                           jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(l_scalar, l_vec, atol=1e-5)


def test_encoder_only_logits():
    from repro.models import encoder_logits
    cfg = reduced(get_config("hubert-xlarge"))
    params = init_params(cfg, KEY)
    batch = _batch(cfg, 2, 16)
    logits = encoder_logits(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits))
