"""Property tests for the static plan verifier (hypothesis).

Two properties over `repro.analysis.synth.random_program`:

* soundness of the cutter w.r.t. the analyzer — `cut_segments` of any
  random placed program verifies **clean** (zero error diagnostics);
* sensitivity — any registered mutation that applies yields at least
  one error diagnostic, carrying the mutation's expected code.

The module skips itself when hypothesis is absent (tier-1 must collect
in a bare venv); `tests/test_analysis.py` carries a seeded, always-run
subset of the same properties.
"""
import numpy as np
import pytest

from repro.analysis.mutate import MUTATIONS, apply_mutation, make_case
from repro.analysis.synth import random_assignment, random_program

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, assume, given, settings  # noqa: E402
from hypothesis import strategies as st                      # noqa: E402

# cap_overflow needs a cost graph with byte annotations, which the
# synthetic generator does not build — covered on a real trace in
# tests/test_analysis.py
_MUTATIONS = sorted(n for n in MUTATIONS if n != "cap_overflow")


def _case(seed, k, n_ops):
    rng = np.random.default_rng(seed)
    prog = random_program(rng, n_ops=n_ops, p_multi=0.3)
    return make_case(prog, random_assignment(rng, prog, k), k), rng


@given(seed=st.integers(0, 2**32 - 1), k=st.integers(1, 4),
       n_ops=st.integers(3, 24))
@settings(max_examples=60, deadline=None)
def test_clean_cut_verifies_clean(seed, k, n_ops):
    case, _ = _case(seed, k, n_ops)
    rep = case.analyze()
    assert not rep.has_errors(), rep.render()


@given(seed=st.integers(0, 2**32 - 1), k=st.integers(2, 4),
       name=st.sampled_from(_MUTATIONS))
@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.filter_too_much])
def test_applied_mutation_yields_expected_error(seed, k, name):
    case, rng = _case(seed, k, 16)
    assume(apply_mutation(name, case, rng))
    rep = case.analyze()
    assert rep.has_errors(), (name, rep.render())
    assert MUTATIONS[name].expect_code in rep.codes(), \
        (name, rep.render())
